"""Shared test configuration: imports, determinism, markers (tier-1 suite).

Responsibilities (kept in one place so ``pytest -q`` works from a bare
checkout, with or without PYTHONPATH=src, with or without hypothesis):

* Path bootstrap — make ``repro`` importable when PYTHONPATH was not set.
* Hypothesis fallback — when the real ``hypothesis`` package is missing,
  install :mod:`tests._hypothesis_shim` so the 7 property-test modules
  collect and run as fixed-example parametrized tests instead of erroring.
* JAX config — force the CPU platform (this container has no accelerator;
  kernels run under ``interpret=True`` / XLA-CPU) and enable x64 so the JAX
  query data plane matches the float64 NumPy reference bit-for-bit in the
  backend-parity tests.
* Seeded RNG fixtures — every test draws from a generator seeded by its own
  node id, so runs are order-independent and reproducible.
* Markers — ``slow`` (multi-minute builds) and ``multidevice`` (subprocess
  host-device meshes), auto-applied by module name and filterable with
  ``-m "not slow"`` / ``-m "not multidevice"``.
"""

from __future__ import annotations

import os
import sys
import zlib

# --- path bootstrap (before any repro import) ----------------------------
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_REPO_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
if os.path.dirname(os.path.abspath(__file__)) not in sys.path:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

# --- hypothesis fallback (before test modules are collected) -------------
try:  # pragma: no cover - exercised implicitly at collection time
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_shim

    _hypothesis_shim.install()

# --- jax config (before any jax computation) -----------------------------
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-minute index builds / end-to-end runs")
    config.addinivalue_line(
        "markers",
        "multidevice: spawns subprocesses with XLA host-device meshes")
    config.addinivalue_line(
        "markers",
        "transport: spawns ProcessTransport worker processes (run in CI "
        "under a hard timeout; deselect with -m 'not transport')")
    config.addinivalue_line(
        "markers",
        "mutation: live-index mutation regression tier (insert/delete/"
        "compact parity and stale-retention guards; select with -m mutation)")


_AUTO_MARKS = {
    "test_multidevice": ("multidevice", "slow"),
    "test_distributed": ("slow",),
    "test_system": ("slow",),
    "test_archs": ("slow",),
    "test_transport": ("transport",),
    "test_obs_transport": ("transport",),
    "test_live": ("mutation",),
}


def pytest_collection_modifyitems(config, items):
    for item in items:
        module = item.nodeid.split("::", 1)[0].rsplit("/", 1)[-1]
        module = module.removesuffix(".py")
        for mark in _AUTO_MARKS.get(module, ()):
            item.add_marker(getattr(pytest.mark, mark))
        if "eight_device" in item.nodeid or "subprocess" in item.nodeid:
            item.add_marker(pytest.mark.multidevice)


@pytest.fixture
def rng(request) -> np.random.Generator:
    """Per-test deterministic generator (seeded by the test's node id)."""
    seed = zlib.crc32(request.node.nodeid.encode("utf-8")) & 0x7FFFFFFF
    return np.random.default_rng(seed)


@pytest.fixture
def seed(request) -> int:
    """Stable integer seed derived from the test's node id."""
    return zlib.crc32(request.node.nodeid.encode("utf-8")) & 0x7FFFFFFF
