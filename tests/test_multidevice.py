"""Real multi-device integration: run sharded train/serve on 4 XLA host
devices in a subprocess (the flag must be set before jax init, so these
tests shell out) and check numerical equivalence with single-device runs.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_config
        from repro.models import transformer as T
        from repro.optim import AdamWConfig, adamw_init
        from repro.train import make_train_step
        from repro.launch import shardings as SH

        assert len(jax.devices()) == 4
        cfg = get_config("llama3-8b").reduced(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256,
            num_heads=4, num_kv_heads=2)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        opt_cfg = AdamWConfig(lr=1e-2)
        state = adamw_init(params, opt_cfg)
        step = make_train_step(cfg, opt_cfg)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, 256, (4, 33), dtype=np.int32))}

        # single-device reference
        p1, s1, m1 = jax.jit(step)(params, state, batch)

        # 2x2 data x model mesh
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        o_sh = SH.opt_shardings(mesh, state)
        b_sh = SH.batch_shardings(mesh, batch)
        with mesh:
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
            p2, s2, m2 = fn(params, state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-3, atol=2e-3)
        print("sharded-train-equivalence OK")
    """))


def test_sharded_decode_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.models import transformer as T
        from repro.launch import shardings as SH

        cfg = get_config("llama3-8b").reduced(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256,
            num_heads=4, num_kv_heads=2)
        params = T.init_params(jax.random.PRNGKey(1), cfg)
        tokens = jnp.ones((4, 16), jnp.int32)
        logits, caches = T.prefill(params, tokens, cfg, buf_len=20)
        step_tok = jnp.ones((4, 1), jnp.int32)
        l1, _ = T.decode_step(params, step_tok, caches, 16, cfg)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        c_sh = SH.cache_shardings(mesh, caches)
        t_sh = SH.batch_shardings(mesh, {"t": step_tok})["t"]
        from jax.sharding import NamedSharding, PartitionSpec as P
        with mesh:
            fn = jax.jit(lambda p, t, c: T.decode_step(p, t, c, 16, cfg),
                         in_shardings=(p_sh, t_sh, c_sh))
            l2, _ = fn(params, step_tok, caches)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=2e-3, atol=2e-3)
        print("sharded-decode-equivalence OK")
    """))


def test_distributed_search_on_4device_mesh():
    print(_run("""
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.core.distributed import distributed_search
        from repro.core.pipeline import SquashConfig, SquashIndex
        from repro.data.synthetic import (default_predicates, ground_truth,
                                          make_vector_dataset)
        ds = make_vector_dataset("sift1m", scale=0.003, num_queries=8)
        preds = default_predicates(ds.attr_cardinality)
        idx = SquashIndex.build(ds.vectors, ds.attributes,
                                SquashConfig(num_partitions=4))
        devs = np.array(jax.devices()).reshape(2, 2)
        mesh = Mesh(devs, ("data", "model"))
        ids, dists = distributed_search(idx, ds.queries, preds, k=5,
                                        mesh=mesh)
        ids_ref, d_ref, _ = idx.search(ds.queries, preds, 5)
        for a, b in zip(ids_ref, ids):
            assert set(a.tolist()) == set(b.tolist())
        print("distributed-search-4dev OK")
    """))


@pytest.mark.xfail(
    strict=False,
    reason="pre-existing seed failure: the 2×2-mesh MoE+MLA forward diverges "
    "from single-device (mean |Δ|≈0.4 — real routing/dispatch divergence "
    "under GSPMD, not tolerance). Needs the dedicated models/moe.py "
    "capacity-ranking debugging pass tracked in ROADMAP.md open items.",
)
def test_sharded_moe_mla_forward_matches_single_device():
    """DeepSeek-style block (MLA attention + MoE FFN) on a 2x2 mesh must
    reproduce single-device logits (no-drop capacity for determinism)."""
    print(_run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.models import transformer as T
        from repro.launch import shardings as SH

        cfg = get_config("deepseek-v2-lite-16b").reduced(
            num_layers=2, d_model=64, d_ff=64, vocab_size=256)
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
        params = T.init_params(jax.random.PRNGKey(3), cfg)
        tokens = jnp.asarray(np.random.default_rng(0).integers(
            0, 256, (4, 16), dtype=np.int32))
        l1, _ = T.forward_train(params, tokens, cfg, remat=False)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        t_sh = SH.batch_shardings(mesh, {"t": tokens})["t"]
        with mesh:
            fn = jax.jit(lambda p, t: T.forward_train(p, t, cfg,
                                                      remat=False)[0],
                         in_shardings=(p_sh, t_sh))
            l2 = fn(params, tokens)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=5e-3, atol=5e-3)
        print("sharded-moe-mla-equivalence OK")
    """))


def test_sharded_mamba_forward_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.models import transformer as T
        from repro.launch import shardings as SH

        cfg = get_config("mamba2-370m").reduced(num_layers=2, d_model=128,
                                                vocab_size=256)
        params = T.init_params(jax.random.PRNGKey(4), cfg)
        tokens = jnp.asarray(np.random.default_rng(1).integers(
            0, 256, (4, 32), dtype=np.int32))
        l1, _ = T.forward_train(params, tokens, cfg, remat=False)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        t_sh = SH.batch_shardings(mesh, {"t": tokens})["t"]
        with mesh:
            fn = jax.jit(lambda p, t: T.forward_train(p, t, cfg,
                                                      remat=False)[0],
                         in_shardings=(p_sh, t_sh))
            l2 = fn(params, tokens)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=5e-3, atol=5e-3)
        print("sharded-mamba-equivalence OK")
    """))
