"""Real multi-device integration: run sharded train/serve on 4 XLA host
devices in a subprocess (the flag must be set before jax init, so these
tests shell out) and check numerical equivalence with single-device runs.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_config
        from repro.models import transformer as T
        from repro.optim import AdamWConfig, adamw_init
        from repro.train import make_train_step
        from repro.launch import shardings as SH

        assert len(jax.devices()) == 4
        cfg = get_config("llama3-8b").reduced(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256,
            num_heads=4, num_kv_heads=2)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        opt_cfg = AdamWConfig(lr=1e-2)
        state = adamw_init(params, opt_cfg)
        step = make_train_step(cfg, opt_cfg)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, 256, (4, 33), dtype=np.int32))}

        # single-device reference
        p1, s1, m1 = jax.jit(step)(params, state, batch)

        # 2x2 data x model mesh
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        o_sh = SH.opt_shardings(mesh, state)
        b_sh = SH.batch_shardings(mesh, batch)
        with mesh:
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
            p2, s2, m2 = fn(params, state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-3, atol=2e-3)
        print("sharded-train-equivalence OK")
    """))


def test_sharded_decode_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.models import transformer as T
        from repro.launch import shardings as SH

        cfg = get_config("llama3-8b").reduced(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256,
            num_heads=4, num_kv_heads=2)
        params = T.init_params(jax.random.PRNGKey(1), cfg)
        tokens = jnp.ones((4, 16), jnp.int32)
        logits, caches = T.prefill(params, tokens, cfg, buf_len=20)
        step_tok = jnp.ones((4, 1), jnp.int32)
        l1, _ = T.decode_step(params, step_tok, caches, 16, cfg)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        c_sh = SH.cache_shardings(mesh, caches)
        t_sh = SH.batch_shardings(mesh, {"t": step_tok})["t"]
        from jax.sharding import NamedSharding, PartitionSpec as P
        with mesh:
            fn = jax.jit(lambda p, t, c: T.decode_step(p, t, c, 16, cfg),
                         in_shardings=(p_sh, t_sh, c_sh))
            l2, _ = fn(params, step_tok, caches)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=2e-3, atol=2e-3)
        print("sharded-decode-equivalence OK")
    """))


def test_distributed_search_on_4device_mesh():
    print(_run("""
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.core.distributed import distributed_search
        from repro.core.pipeline import SquashConfig, SquashIndex
        from repro.data.synthetic import (default_predicates, ground_truth,
                                          make_vector_dataset)
        ds = make_vector_dataset("sift1m", scale=0.003, num_queries=8)
        preds = default_predicates(ds.attr_cardinality)
        idx = SquashIndex.build(ds.vectors, ds.attributes,
                                SquashConfig(num_partitions=4))
        devs = np.array(jax.devices()).reshape(2, 2)
        mesh = Mesh(devs, ("data", "model"))
        ids, dists = distributed_search(idx, ds.queries, preds, k=5,
                                        mesh=mesh)
        ids_ref, d_ref, _ = idx.search(ds.queries, preds, 5)
        for a, b in zip(ids_ref, ids):
            assert set(a.tolist()) == set(b.tolist())
        print("distributed-search-4dev OK")
    """))


@pytest.mark.xfail(
    strict=False,
    reason="pre-existing seed failure, now narrowed: the MoE dispatch half "
    "(a concat-padded gather miscompiling under GSPMD — see "
    "test_sharded_moe_dispatch_gather_repro) is fixed and the MoE-only "
    "forward matches bitwise (test_sharded_moe_ffn_matches_single_device); "
    "the residual 2×2-mesh divergence (mean |Δ|≈0.4) therefore lives in "
    "the MLA attention path, tracked in ROADMAP.md open items.",
)
def test_sharded_moe_mla_forward_matches_single_device():
    """DeepSeek-style block (MLA attention + MoE FFN) on a 2x2 mesh must
    reproduce single-device logits (no-drop capacity for determinism)."""
    print(_run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.models import transformer as T
        from repro.launch import shardings as SH

        cfg = get_config("deepseek-v2-lite-16b").reduced(
            num_layers=2, d_model=64, d_ff=64, vocab_size=256)
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
        params = T.init_params(jax.random.PRNGKey(3), cfg)
        tokens = jnp.asarray(np.random.default_rng(0).integers(
            0, 256, (4, 16), dtype=np.int32))
        l1, _ = T.forward_train(params, tokens, cfg, remat=False)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        t_sh = SH.batch_shardings(mesh, {"t": tokens})["t"]
        with mesh:
            fn = jax.jit(lambda p, t: T.forward_train(p, t, cfg,
                                                      remat=False)[0],
                         in_shardings=(p_sh, t_sh))
            l2 = fn(params, tokens)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=5e-3, atol=5e-3)
        print("sharded-moe-mla-equivalence OK")
    """))


def test_sharded_moe_ffn_matches_single_device():
    """Narrowed repro below the full MoE+MLA xfail: *only* the MoE block
    (router → capacity ranking → dispatch → grouped experts → combine) on
    the 2×2 mesh, expert stacks sharded over `model`, tokens over `data`.
    Exact equality — the dispatch/combine gathers are the risk surface."""
    print(_run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_config
        from repro.models import moe as M

        cfg = get_config("deepseek-v2-lite-16b").reduced(
            num_layers=2, d_model=64, d_ff=64, vocab_size=256)
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
        params = M.init_moe(jax.random.PRNGKey(3), cfg)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(4, 16, 64)).astype(np.float32))
        y1, aux1 = M.moe_ffn(params, x, cfg)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = jax.tree_util.tree_map(
            lambda a: NamedSharding(
                mesh, P("model", None, None) if a.ndim == 3 else P()),
            params)
        with mesh:
            fn = jax.jit(lambda p, t: M.moe_ffn(p, t, cfg),
                         in_shardings=(p_sh,
                                       NamedSharding(mesh,
                                                     P("data", None, None))))
            y2, aux2 = fn(params, x)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
        np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-6)
        print("sharded-moe-only-equivalence OK")
    """))


@pytest.mark.xfail(
    strict=False,
    reason="minimal repro of the root cause behind the historical MoE "
    "divergence: gathering through a concatenate whose axis-0 operand is "
    "sharded returns wrong values under GSPMD on the host-device mesh. "
    "models/moe.py now uses masked safe-gathers instead; this test pins "
    "the underlying XLA behavior so we notice if/when it is fixed.",
)
def test_sharded_moe_dispatch_gather_repro():
    """The dispatch gather in its smallest form: identical indices, identical
    operands, concat-pad gather vs masked gather — only the former diverges
    when the gathered-from array is sharded on axis 0."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P

        t, d, e, c = 64, 64, 4, 128
        rng = np.random.default_rng(0)
        xt = jnp.asarray(rng.normal(size=(t, d)).astype(np.float32))
        tok = jnp.asarray(rng.integers(0, t + 1, e * c), dtype=jnp.int32)

        def concat_pad_gather(xt, tok):
            xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)],
                                     axis=0)
            return xt_pad[tok].reshape(e, c, d)

        ref = concat_pad_gather(xt, tok)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        with mesh:
            out = jax.jit(concat_pad_gather,
                          in_shardings=(NamedSharding(mesh, P("data", None)),
                                        NamedSharding(mesh, P())))(xt, tok)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
        print("concat-pad-gather-sharded OK")
    """))


def test_sharded_mamba_forward_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.models import transformer as T
        from repro.launch import shardings as SH

        cfg = get_config("mamba2-370m").reduced(num_layers=2, d_model=128,
                                                vocab_size=256)
        params = T.init_params(jax.random.PRNGKey(4), cfg)
        tokens = jnp.asarray(np.random.default_rng(1).integers(
            0, 256, (4, 32), dtype=np.int32))
        l1, _ = T.forward_train(params, tokens, cfg, remat=False)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        t_sh = SH.batch_shardings(mesh, {"t": tokens})["t"]
        with mesh:
            fn = jax.jit(lambda p, t: T.forward_train(p, t, cfg,
                                                      remat=False)[0],
                         in_shardings=(p_sh, t_sh))
            l2 = fn(params, tokens)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=5e-3, atol=5e-3)
        print("sharded-mamba-equivalence OK")
    """))
