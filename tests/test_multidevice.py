"""Real multi-device integration: run sharded train/serve on 4 XLA host
devices in a subprocess (the flag must be set before jax init, so these
tests shell out) and check numerical equivalence with single-device runs.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_config
        from repro.models import transformer as T
        from repro.optim import AdamWConfig, adamw_init
        from repro.train import make_train_step
        from repro.launch import shardings as SH

        assert len(jax.devices()) == 4
        cfg = get_config("llama3-8b").reduced(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256,
            num_heads=4, num_kv_heads=2)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        opt_cfg = AdamWConfig(lr=1e-2)
        state = adamw_init(params, opt_cfg)
        step = make_train_step(cfg, opt_cfg)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, 256, (4, 33), dtype=np.int32))}

        # single-device reference
        p1, s1, m1 = jax.jit(step)(params, state, batch)

        # 2x2 data x model mesh
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        o_sh = SH.opt_shardings(mesh, state)
        b_sh = SH.batch_shardings(mesh, batch)
        with mesh:
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
            p2, s2, m2 = fn(params, state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-3, atol=2e-3)
        print("sharded-train-equivalence OK")
    """))


def test_sharded_decode_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.models import transformer as T
        from repro.launch import shardings as SH

        cfg = get_config("llama3-8b").reduced(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256,
            num_heads=4, num_kv_heads=2)
        params = T.init_params(jax.random.PRNGKey(1), cfg)
        tokens = jnp.ones((4, 16), jnp.int32)
        logits, caches = T.prefill(params, tokens, cfg, buf_len=20)
        step_tok = jnp.ones((4, 1), jnp.int32)
        l1, _ = T.decode_step(params, step_tok, caches, 16, cfg)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        c_sh = SH.cache_shardings(mesh, caches)
        t_sh = SH.batch_shardings(mesh, {"t": step_tok})["t"]
        from jax.sharding import NamedSharding, PartitionSpec as P
        with mesh:
            fn = jax.jit(lambda p, t, c: T.decode_step(p, t, c, 16, cfg),
                         in_shardings=(p_sh, t_sh, c_sh))
            l2, _ = fn(params, step_tok, caches)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=2e-3, atol=2e-3)
        print("sharded-decode-equivalence OK")
    """))


def test_distributed_search_on_4device_mesh():
    print(_run("""
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.core.distributed import distributed_search
        from repro.core.pipeline import SquashConfig, SquashIndex
        from repro.data.synthetic import (default_predicates, ground_truth,
                                          make_vector_dataset)
        ds = make_vector_dataset("sift1m", scale=0.003, num_queries=8)
        preds = default_predicates(ds.attr_cardinality)
        idx = SquashIndex.build(ds.vectors, ds.attributes,
                                SquashConfig(num_partitions=4))
        devs = np.array(jax.devices()).reshape(2, 2)
        mesh = Mesh(devs, ("data", "model"))
        ids, dists = distributed_search(idx, ds.queries, preds, k=5,
                                        mesh=mesh)
        ids_ref, d_ref, _ = idx.search(ds.queries, preds, 5)
        for a, b in zip(ids_ref, ids):
            assert set(a.tolist()) == set(b.tolist())
        print("distributed-search-4dev OK")
    """))


def test_sharded_moe_mla_forward_matches_single_device():
    """DeepSeek-style block (MLA attention + MoE FFN) on a 2x2 mesh must
    reproduce single-device logits (no-drop capacity for determinism).

    Seed failure, fixed in two steps: the MoE dispatch half was a
    concat-padded gather miscompiling under GSPMD (masked safe-gathers in
    models/moe.py, PR 3); the residual mean |Δ|≈0.4 was the vocab-sharded
    embedding gather feeding the lax.scan over stacked MLA blocks — the MLA
    sub-parity tests below pin that the rope/absorb math itself was always
    exact, and forward_train now constrains the embed output to
    batch-over-`data` before the scan (raw-XLA behavior still pinned in
    test_sharded_mla_scan_after_embed_repro)."""
    print(_run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.models import transformer as T
        from repro.launch import shardings as SH

        cfg = get_config("deepseek-v2-lite-16b").reduced(
            num_layers=2, d_model=64, d_ff=64, vocab_size=256)
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
        params = T.init_params(jax.random.PRNGKey(3), cfg)
        tokens = jnp.asarray(np.random.default_rng(0).integers(
            0, 256, (4, 16), dtype=np.int32))
        l1, _ = T.forward_train(params, tokens, cfg, remat=False)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        t_sh = SH.batch_shardings(mesh, {"t": tokens})["t"]
        with mesh:
            fn = jax.jit(lambda p, t: T.forward_train(p, t, cfg,
                                                      remat=False)[0],
                         in_shardings=(p_sh, t_sh))
            l2 = fn(params, tokens)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=5e-3, atol=5e-3)
        print("sharded-moe-mla-equivalence OK")
    """))


def test_sharded_moe_ffn_matches_single_device():
    """Narrowed repro below the full MoE+MLA xfail: *only* the MoE block
    (router → capacity ranking → dispatch → grouped experts → combine) on
    the 2×2 mesh, expert stacks sharded over `model`, tokens over `data`.
    Exact equality — the dispatch/combine gathers are the risk surface."""
    print(_run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_config
        from repro.models import moe as M

        cfg = get_config("deepseek-v2-lite-16b").reduced(
            num_layers=2, d_model=64, d_ff=64, vocab_size=256)
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
        params = M.init_moe(jax.random.PRNGKey(3), cfg)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(4, 16, 64)).astype(np.float32))
        y1, aux1 = M.moe_ffn(params, x, cfg)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = jax.tree_util.tree_map(
            lambda a: NamedSharding(
                mesh, P("model", None, None) if a.ndim == 3 else P()),
            params)
        with mesh:
            fn = jax.jit(lambda p, t: M.moe_ffn(p, t, cfg),
                         in_shardings=(p_sh,
                                       NamedSharding(mesh,
                                                     P("data", None, None))))
            y2, aux2 = fn(params, x)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
        np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-6)
        print("sharded-moe-only-equivalence OK")
    """))


@pytest.mark.xfail(
    strict=False,
    reason="minimal repro of the root cause behind the historical MoE "
    "divergence: gathering through a concatenate whose axis-0 operand is "
    "sharded returns wrong values under GSPMD on the host-device mesh. "
    "models/moe.py now uses masked safe-gathers instead; this test pins "
    "the underlying XLA behavior so we notice if/when it is fixed.",
)
def test_sharded_moe_dispatch_gather_repro():
    """The dispatch gather in its smallest form: identical indices, identical
    operands, concat-pad gather vs masked gather — only the former diverges
    when the gathered-from array is sharded on axis 0."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P

        t, d, e, c = 64, 64, 4, 128
        rng = np.random.default_rng(0)
        xt = jnp.asarray(rng.normal(size=(t, d)).astype(np.float32))
        tok = jnp.asarray(rng.integers(0, t + 1, e * c), dtype=jnp.int32)

        def concat_pad_gather(xt, tok):
            xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)],
                                     axis=0)
            return xt_pad[tok].reshape(e, c, d)

        ref = concat_pad_gather(xt, tok)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        with mesh:
            out = jax.jit(concat_pad_gather,
                          in_shardings=(NamedSharding(mesh, P("data", None)),
                                        NamedSharding(mesh, P())))(xt, tok)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
        print("concat-pad-gather-sharded OK")
    """))


def test_sharded_mla_attention_matches_single_device():
    """MLA sub-parity 1/3: the attention block alone — rope application,
    latent down/up projections, absorbed einsums — under the production
    weight shardings (d_in over `data`, d_out over `model`) on the 2×2 mesh
    must reproduce single-device outputs. This passing pins the full-forward
    divergence *outside* the MLA math."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_config
        from repro.models import attention as A

        cfg = get_config("deepseek-v2-lite-16b").reduced(
            num_layers=2, d_model=64, d_ff=64, vocab_size=256)
        params = A.init_mla(jax.random.PRNGKey(3), cfg)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(4, 16, 64)).astype(np.float32))
        pos = jnp.broadcast_to(jnp.arange(16)[None, :],
                               (4, 16)).astype(jnp.int32)
        y1 = A.mla_train(params, x, pos, cfg)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        specs = {"wq": P("data", "model"), "w_dkv": P("data", "model"),
                 "w_uk": P("data", "model"), "w_uv": P("data", "model"),
                 "wo": P("model", "data")}
        p_sh = {k: {"w": NamedSharding(mesh, specs[k])} for k in params}
        x_sh = NamedSharding(mesh, P("data", None, None))
        with mesh:
            fn = jax.jit(lambda p, t: A.mla_train(p, t, pos, cfg),
                         in_shardings=(p_sh, x_sh))
            y2 = fn(params, x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)
        print("sharded-mla-attention-equivalence OK")
    """))


def test_mla_absorbed_decode_matches_materialized():
    """MLA sub-parity 2/3: the rope/absorb split itself. Absorbed decode
    (q projected into latent space, W_uk folded into the query) must equal
    the materialized train-form attention at the same position — if the
    full-forward divergence lived in the rope/absorb math this would fail
    on a single device. Exact prefix parity is also pinned."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.models import attention as A

        cfg = get_config("deepseek-v2-lite-16b").reduced(
            num_layers=2, d_model=64, d_ff=64, vocab_size=256)
        rng = np.random.default_rng(0)
        b, s = 2, 9
        x = jnp.asarray(rng.normal(size=(b, s + 1, 64)).astype(np.float32))
        pos = jnp.broadcast_to(jnp.arange(s + 1)[None, :],
                               (b, s + 1)).astype(jnp.int32)
        params = A.init_mla(jax.random.PRNGKey(5), cfg)

        y_full = A.mla_train(params, x, pos, cfg)
        y_pre, cache = A.mla_prefill(params, x[:, :s], pos[:, :s], cfg,
                                     buf_len=s + 1)
        y_dec, _ = A.mla_decode(params, x[:, s:], cache, s, cfg)
        np.testing.assert_array_equal(np.asarray(y_full[:, :s]),
                                      np.asarray(y_pre))
        np.testing.assert_allclose(np.asarray(y_full[:, s]),
                                   np.asarray(y_dec[:, 0]),
                                   rtol=1e-5, atol=1e-5)
        print("mla-absorbed-decode-equivalence OK")
    """))


@pytest.mark.xfail(
    strict=False,
    reason="minimal repro of the residual MoE+MLA forward divergence: the "
    "raw vocab-sharded embedding gather (L.embed, bypassing the sharding "
    "hint _embed_inputs now applies as the production fix) feeding a "
    "lax.scan over stacked MLA blocks returns wrong values under GSPMD on "
    "the host-device mesh. The same scan fed pre-sharded activations "
    "matches, the unrolled loop over the same blocks matches, and GQA "
    "blocks under the same embed+scan match — so neither the rope/absorb "
    "math nor the scan alone is at fault. Pinned so we notice if/when XLA "
    "fixes it.",
)
def test_sharded_mla_scan_after_embed_repro():
    """MLA sub-parity 3/3: raw embed gather → lax.scan(stacked MLA blocks),
    the composition forward_train used before _embed_inputs gained its
    sharding hint, with control arms asserted inside."""
    print(_run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_config
        from repro.models import layers as L
        from repro.models import transformer as T
        from repro.launch import shardings as SH

        cfg = get_config("deepseek-v2-lite-16b").reduced(
            num_layers=2, d_model=64, d_ff=64, vocab_size=256)
        cfg = dataclasses.replace(cfg, num_experts=0)
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, 256, (4, 16), dtype=np.int32))
        params = T.init_params(jax.random.PRNGKey(3), cfg)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        t_sh = SH.batch_shardings(mesh, {"t": tokens})["t"]
        pos = T.make_positions(4, 16)

        def embed_scan(p, t):
            x = L.embed(p["embed"], t)      # raw gather, no sharding hint
            def body(carry, lp):
                y, a = T.block_train(lp, carry, pos, cfg, kind="mla")
                return y, a
            x, _ = jax.lax.scan(body, x, p["blocks"])
            return x

        def embed_unroll(p, t):
            x = L.embed(p["embed"], t)      # raw gather, no sharding hint
            for i in range(2):
                lp = jax.tree_util.tree_map(lambda a: a[i], p["blocks"])
                x, _ = T.block_train(lp, x, pos, cfg, kind="mla")
            return x

        r1 = embed_scan(params, tokens)
        with mesh:
            r2 = jax.jit(embed_scan,
                         in_shardings=(p_sh, t_sh))(params, tokens)
            r2u = jax.jit(embed_unroll,
                          in_shardings=(p_sh, t_sh))(params, tokens)
        # Control arm: the unrolled loop over the SAME sharded params
        # matches — the scan is the necessary ingredient.
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2u),
                                   rtol=1e-4, atol=1e-5)
        # Failing arm: the scanned composition diverges (mean |delta|~0.4).
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                                   rtol=1e-4, atol=1e-5)
        print("sharded-mla-scan-after-embed OK")
    """))


def test_sharded_mamba_forward_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.models import transformer as T
        from repro.launch import shardings as SH

        cfg = get_config("mamba2-370m").reduced(num_layers=2, d_model=128,
                                                vocab_size=256)
        params = T.init_params(jax.random.PRNGKey(4), cfg)
        tokens = jnp.asarray(np.random.default_rng(1).integers(
            0, 256, (4, 32), dtype=np.int32))
        l1, _ = T.forward_train(params, tokens, cfg, remat=False)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p_sh = SH.params_shardings(mesh, params)
        t_sh = SH.batch_shardings(mesh, {"t": tokens})["t"]
        with mesh:
            fn = jax.jit(lambda p, t: T.forward_train(p, t, cfg,
                                                      remat=False)[0],
                         in_shardings=(p_sh, t_sh))
            l2 = fn(params, tokens)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=5e-3, atol=5e-3)
        print("sharded-mamba-equivalence OK")
    """))
