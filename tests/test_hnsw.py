"""HNSW baseline correctness: graph invariants + recall on easy data."""

import numpy as np
import pytest

from repro.core.hnsw import HNSWConfig, HNSWIndex
from repro.data.synthetic import (default_predicates, ground_truth,
                                  make_vector_dataset)


@pytest.fixture(scope="module")
def ds():
    return make_vector_dataset("sift1m", scale=0.002, num_queries=12, seed=1)


@pytest.fixture(scope="module")
def index(ds):
    return HNSWIndex(ds.vectors, HNSWConfig(m=12, ef_construction=64),
                     attributes=ds.attributes)


def test_graph_degree_bounds(index):
    cfg = index.config
    for lvl, adj in enumerate(index._adj):
        cap = 2 * cfg.m if lvl == 0 else cfg.m
        for node, nbrs in adj.items():
            assert len(nbrs) <= cap
            assert node not in nbrs


def test_every_node_reachable_on_layer0(index):
    adj = index._adj[0]
    n = index.vectors.shape[0]
    seen = set()
    stack = [index._entry]
    while stack:
        u = stack.pop()
        if u in seen:
            continue
        seen.add(u)
        stack.extend(adj.get(u, []))
    # undirected reachability via reverse edges too
    if len(seen) < n:
        rev = {}
        for u, nbrs in adj.items():
            for v in nbrs:
                rev.setdefault(v, []).append(u)
        stack = list(seen)
        while stack:
            u = stack.pop()
            for v in adj.get(u, []) + rev.get(u, []):
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
    assert len(seen) >= 0.99 * n, "layer-0 graph must be (near) connected"


def test_unfiltered_recall(ds, index):
    gt, _ = ground_truth(ds, [], k=10)
    ids, dists = index.search(ds.queries, k=10, ef=96)
    hits = sum(len(set(ids[i]) & set(gt[i])) for i in range(len(ids)))
    assert hits / gt.size >= 0.85
    # distances ascending
    for row in dists:
        fin = row[np.isfinite(row)]
        assert np.all(np.diff(fin) >= -1e-6)


def test_filtered_results_satisfy_predicate(ds, index):
    preds = default_predicates(ds.attr_cardinality)
    ids, _ = index.search_filtered(ds.queries, preds, k=5, expansion=4)
    for row in ids:
        for vid in row:
            if vid >= 0:
                for p in preds:
                    assert p.eval(np.asarray([ds.attributes[vid, p.attr]]))[0]
