"""NumPy vs JAX query data-plane parity (pipeline backend switch).

The batched jitted plane (core/dataplane.py) must return **bitwise-identical
ids** to the per-query NumPy reference for every supported configuration:
selective predicates, empty-result predicates, unfiltered search, no-refine
mode, both ADC formulations (dense-table kernel for small M+1, direct
boundary gathers for tall tables), and k larger than some partitions'
candidate sets. SearchStats counters must agree exactly, and the plane must
trace exactly once per (Q, k, index shape).
"""

import numpy as np
import pytest

from repro.core import dataplane
from repro.core.attributes import Predicate
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data import synthetic
from repro.serve.vector_service import ServiceConfig, VectorSearchService


@pytest.fixture(scope="module")
def built():
    ds = synthetic.make_vector_dataset("sift1m", scale=0.008, num_queries=24,
                                       seed=5)
    preds = synthetic.default_predicates()
    cfg = SquashConfig(num_partitions=6, kmeans_iters=5, lloyd_iters=8)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=5)
    return ds, preds, index


def _both(index, queries, preds, k):
    ids_n, d_n, s_n = index.search(queries, preds, k=k, backend="numpy")
    ids_j, d_j, s_j = index.search(queries, preds, k=k, backend="jax")
    return (ids_n, d_n, s_n), (ids_j, d_j, s_j)


def test_selective_predicates_identical(built):
    ds, preds, index = built
    (ids_n, d_n, s_n), (ids_j, d_j, s_j) = _both(index, ds.queries, preds, 10)
    np.testing.assert_array_equal(ids_n, ids_j)
    finite = np.isfinite(d_n)
    np.testing.assert_array_equal(finite, np.isfinite(d_j))
    np.testing.assert_allclose(d_j[finite], d_n[finite], rtol=1e-9, atol=1e-9)
    assert s_n == s_j


def test_unfiltered_identical(built):
    ds, _, index = built
    (ids_n, _, s_n), (ids_j, _, s_j) = _both(index, ds.queries, [], 10)
    np.testing.assert_array_equal(ids_n, ids_j)
    assert s_n == s_j


def test_empty_result_predicate(built):
    ds, _, index = built
    impossible = [Predicate(attr=0, op="=", lo=1e9)]
    (ids_n, d_n, s_n), (ids_j, d_j, s_j) = _both(
        index, ds.queries[:5], impossible, 10)
    assert (ids_n == -1).all() and (ids_j == -1).all()
    assert np.isinf(d_n).all() and np.isinf(d_j).all()
    assert s_n == s_j
    assert s_j.hamming_in == 0 and s_j.refined == 0


def test_k_exceeds_candidates(built):
    """k larger than some partitions' filtered candidate sets: -1 padding in
    both planes, identical placement."""
    ds, _, index = built
    narrow = [Predicate(attr=0, op="=", lo=float(ds.attributes[0, 0]))]
    (ids_n, d_n, _), (ids_j, d_j, _) = _both(index, ds.queries[:6], narrow, 50)
    np.testing.assert_array_equal(ids_n, ids_j)
    np.testing.assert_array_equal(np.isfinite(d_n), np.isfinite(d_j))


def test_single_query_and_odd_batches(built):
    ds, preds, index = built
    for qn in (1, 3):
        (ids_n, _, _), (ids_j, _, _) = _both(index, ds.queries[:qn], preds, 7)
        np.testing.assert_array_equal(ids_n, ids_j)


def test_no_refine_backend_parity(built):
    ds, preds, _ = built
    cfg = SquashConfig(num_partitions=4, enable_refine=False, kmeans_iters=4,
                       lloyd_iters=6)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=6)
    (ids_n, d_n, s_n), (ids_j, d_j, s_j) = _both(index, ds.queries[:8],
                                                 preds, 10)
    np.testing.assert_array_equal(ids_n, ids_j)
    assert s_n == s_j and s_n.refined == 0


def test_table_kernel_path_parity(built):
    """max_bits_per_dim small → M+1 under ADC_TABLE_MAX_M1 → the dense-table
    one-hot kernel path (not the boundary-gather path) must match too."""
    ds, preds, _ = built
    cfg = SquashConfig(num_partitions=4, kmeans_iters=4, lloyd_iters=6,
                       max_bits_per_dim=5)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=7)
    m1 = max(p.quant.boundaries.shape[0] for p in index.parts)
    assert m1 <= dataplane.ADC_TABLE_MAX_M1, "config no longer hits table path"
    (ids_n, _, s_n), (ids_j, _, s_j) = _both(index, ds.queries[:10], preds, 10)
    np.testing.assert_array_equal(ids_n, ids_j)
    assert s_n == s_j


def test_config_backend_field_and_validation(built):
    ds, preds, index = built
    index.config.backend = "jax"
    try:
        ids_cfg, _, _ = index.search(ds.queries[:4], preds, k=5)
    finally:
        index.config.backend = "numpy"
    ids_j, _, _ = index.search(ds.queries[:4], preds, k=5, backend="jax")
    np.testing.assert_array_equal(ids_cfg, ids_j)
    with pytest.raises(ValueError, match="unknown backend"):
        index.search(ds.queries[:2], preds, k=5, backend="torch")


def test_jax_plane_traces_once_per_shape(built):
    """One trace per (Q, k, index shape): repeated same-shape calls reuse the
    compiled plane; a new Q adds exactly one trace."""
    ds, preds, index = built
    base = index._trace_counter[0]
    index.search(ds.queries[:8], preds, k=10, backend="jax")
    after_first = index._trace_counter[0]
    index.search(ds.queries[:8], preds, k=10, backend="jax")
    index.search(ds.queries[8:16], preds, k=10, backend="jax")
    assert index._trace_counter[0] == after_first  # same (Q, k): no retrace
    index.search(ds.queries[:3], preds, k=10, backend="jax")
    assert index._trace_counter[0] == after_first + 1  # new Q: one trace


def test_stage_counts_match_reference_formulas():
    cfg = SquashConfig(min_hamming_keep=8, hamming_perc=10.0, refine_ratio=2.0)
    n_cand = np.array([[0, 1, 7, 8, 50, 500, 3000]], dtype=np.int32)
    keep, take = dataplane.stage_counts(n_cand, cfg, k=10)
    for i, n in enumerate(n_cand[0]):
        n = int(n)
        if n == 0:
            ref_keep = 0
        else:
            ref_keep = max(min(cfg.min_hamming_keep, n),
                           int(np.ceil(n * cfg.hamming_perc / 100.0)))
            ref_keep = min(ref_keep, n)
        assert keep[0, i] == ref_keep
        assert take[0, i] == min(int(np.ceil(cfg.refine_ratio * 10)), ref_keep)
    keep_s, take_s = dataplane.static_counts(3000, cfg, k=10)
    assert keep_s == max(8, 300) and take_s == 20
    assert (keep <= keep_s).all() and (take <= take_s).all()


def test_service_routes_and_accounts(built):
    ds, preds, index = built
    svc = VectorSearchService(index, ServiceConfig(backend="auto"))
    assert svc.resolve_backend(1) == "numpy"
    assert svc.resolve_backend(64) == "jax"
    ids_b, _, _ = svc.query(ds.queries[:8], preds)          # auto → jax
    ids_1, _, _ = svc.query(ds.queries[:1], preds)          # auto → numpy
    assert svc.queries_served["jax"] == 8
    assert svc.queries_served["numpy"] == 1
    ids_ref, _, _ = index.search(ds.queries[:8], preds, k=10, backend="numpy")
    np.testing.assert_array_equal(ids_b, ids_ref)
    assert svc.stats.queries == 9
    # explicit "auto" must route, not leak into SquashIndex.search
    ids_a, _, _ = svc.query(ds.queries[:8], preds, backend="auto")
    np.testing.assert_array_equal(ids_a, ids_ref)
    with pytest.raises(ValueError):
        VectorSearchService(index, ServiceConfig(backend="torch"))


def test_service_validates_per_call_backend(built):
    """Regression: a bad per-call backend string must fail at the service
    boundary — before touching the index — and leave accounting unchanged."""
    ds, preds, index = built
    svc = VectorSearchService(index, ServiceConfig(backend="auto"))
    before_requests = svc.requests
    before_stats = svc.stats.queries
    with pytest.raises(ValueError, match="unknown backend 'torch'"):
        svc.query(ds.queries[:2], preds, backend="torch")
    assert svc.requests == before_requests
    assert svc.stats.queries == before_stats
    assert all(v == 0 for v in svc.queries_served.values())
